"""Pipelined-training workload — stage-partitioned programs over pods.

Pods become pipeline stages: the scenario's program is split into
contiguous per-stage segments, stage 0's host data-loads each microbatch,
and every stage ships its activations to the next stage's host over the
fabric (``pipe_send`` → ``LinkTransfer`` → ``pipe_recv``).  All stages of
one microbatch share a trace (the host weaver keys traces by ``step``),
so a woven microbatch reads as::

    HostStep step=m (host0/stage0)          HostStep step=m (host1/stage1)
    ├── DataLoad                            ├── [pipe_recv event]
    ├── Dispatch ×chips → DeviceProgram     ├── Dispatch ×chips → ...
    └── [pipe_send event]                   └── [pipe_send event] ...
         └── LinkTransfer act.m<m>.s0 ───────▶ (parents under stage0's step)

Cross-pod (DCN-group) ops inside a stage segment are re-homed onto the
stage's ICI ring: pods are pipeline stages here, so there is no data
parallel replica group to all-reduce with across pods.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import ClassVar, List, Optional, TYPE_CHECKING

from ..hostsim import _short
from ..workload import ProgramSpec, Workload, register_workload

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..cluster import ClusterOrchestrator


def split_stages(program: ProgramSpec, n_stages: int) -> List[ProgramSpec]:
    """Partition a program into ``n_stages`` contiguous per-stage segments.

    Ops are split evenly by position (the layer-granular programs this
    repo builds make position a good proxy for cost); DCN-group ops are
    re-homed to the stage's ICI ring (see module docstring).  Stage ``s``'s
    program is named ``<name>.stage<s>`` so dispatch keys, collective
    rendezvous and span names all stay stage-distinct.
    """
    ops = [o if o.group != "dcn" else replace(o, group="ici") for o in program.ops]
    bounds = [round(s * len(ops) / n_stages) for s in range(n_stages + 1)]
    return [
        ProgramSpec(name=f"{program.name}.stage{s}", ops=ops[bounds[s]:bounds[s + 1]])
        for s in range(n_stages)
    ]


@register_workload
@dataclass
class PipelinedTraining(Workload):
    """Microbatch pipeline across pods with activations over the fabric.

    Knobs beyond the standard five:

    * ``n_microbatches``   — microbatches pushed through the pipeline
      (default ``2 * n_steps``: sweep size overrides scale depth);
    * ``activation_bytes`` — inter-stage activation payload per microbatch.
    """

    workload_name: ClassVar[str] = "pipeline"

    n_microbatches: Optional[int] = None
    activation_bytes: int = 4 << 20

    @property
    def total_microbatches(self) -> int:
        """Effective depth (``n_microbatches`` or ``2 * n_steps``)."""
        return (self.n_microbatches if self.n_microbatches is not None
                else 2 * self.n_steps)

    def describe(self) -> str:
        return (f"pipeline({self.total_microbatches} microbatches, "
                f"{self.activation_bytes >> 20} MiB activations)")

    def drive(self, cluster: "ClusterOrchestrator") -> None:
        """Arm the stage hosts: stage 0 feeds, stages forward activations."""
        hosts = self.serving_hosts(cluster)
        if not hosts:
            raise ValueError("pipeline workload needs at least one chip-bearing host")
        stages = split_stages(self.program, len(hosts))
        n_mb = self.total_microbatches
        last = len(hosts) - 1
        # per-stage serial execution: a stage processes microbatches in
        # order; arrivals ahead of the current microbatch wait in `ready`
        ready = [set() for _ in hosts]
        busy = [False] * len(hosts)
        next_mb = [0] * len(hosts)
        finished = {"n": 0}

        for h in hosts:
            self.start_clock_telemetry(h)

        def try_start(s: int) -> None:
            if busy[s] or next_mb[s] >= n_mb:
                return
            m = next_mb[s]
            if s > 0 and m not in ready[s]:
                return
            busy[s] = True
            next_mb[s] += 1
            process(s, m)

        def process(s: int, m: int) -> None:
            h = hosts[s]
            h.log_event("step_begin", step=m)
            if s > 0:
                h.log_event("pipe_recv", mb=m, stage=s)
                stall = h.consume_stall(step=m)
                h.sim.call_after(stall, lambda: dispatch_stage(s, m))
            else:
                h.log_event("data_load_begin", step=m)
                wait = h.data_load_ps + h.consume_stall(step=m)

                def loaded() -> None:
                    h.log_event("data_load_end", step=m,
                                bytes=h.batch_bytes_per_chip * len(h.chips))
                    dispatch_stage(s, m)

                h.sim.call_after(wait, loaded)

        def dispatch_stage(s: int, m: int) -> None:
            h = hosts[s]
            prog = stages[s]
            pending = {"n": len(h.chips)}

            def chip_done(chip: str, _t: int) -> None:
                h.log_event("program_retire", chip=_short(chip), step=m,
                            program=prog.name)
                pending["n"] -= 1
                if pending["n"] == 0:
                    stage_done(s, m)

            for chip in h.chips:
                h.log_event("program_enqueue", chip=_short(chip), step=m,
                            program=prog.name)
                cluster.dispatch(h, chip, prog, m, chip_done)

        def stage_done(s: int, m: int) -> None:
            h = hosts[s]
            if s < last:
                cid = f"act.m{m}.s{s}"
                h.log_event("pipe_send", mb=m, stage=s,
                            bytes=self.activation_bytes, chunk=cid)
                cluster.net.transfer(
                    h.name, hosts[s + 1].name, self.activation_bytes,
                    meta={"mb": m, "stage": s}, chunk_id=cid,
                    on_delivered=lambda _t: activation_arrived(s + 1, m),
                )
            h.log_event("step_end", step=m)
            busy[s] = False
            if s == last:
                finished["n"] += 1
                if finished["n"] == n_mb:
                    cluster.net.stop_all_flows()
            try_start(s)

        def activation_arrived(s: int, m: int) -> None:
            ready[s].add(m)
            try_start(s)

        try_start(0)
