"""repro — Columbo (modular full-system-simulation tracing) built into a
multi-pod JAX training/serving framework.

Subpackages:
  core         Columbo: event streams, pipelines, SpanWeavers, exporters
  sim          component simulators (chip/host/interconnect) + orchestrator
  models       composable model stack (10 assigned architectures)
  training     AdamW, train_step, Trainer
  serving      KV caches, prefill/decode, batched engine
  data         deterministic synthetic pipeline
  checkpoint   atomic sharded checkpoints + elastic restore
  distributed  compression, pipeline parallelism
  kernels      Pallas TPU kernels + jnp oracles
  configs      architecture registry + input shapes
  launch       meshes, dry-run, train/serve/trace CLIs
"""

__version__ = "1.0.0"
