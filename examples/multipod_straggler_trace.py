"""Multi-pod straggler diagnosis — the paper's methodology applied to this
framework's own workload.

A 2-pod cluster runs a training program derived from a REAL dry-run
artifact (the compiled collective schedule + aggregate costs of an assigned
architecture).  One chip is slowed 3x; background traffic contends the DCN
link.  Columbo traces localize both: the slow chip dominates the Op-span
breakdown, and the cross-pod gradient all-reduce's LinkTransfer spans show
the queueing on the contended link.

    PYTHONPATH=src python examples/multipod_straggler_trace.py --arch olmo-1b
"""
import argparse
import json
import os

from repro.core import (
    ChromeTraceExporter,
    TraceSession,
    assemble_traces,
    component_breakdown,
    straggler_report,
)
from repro.sim import run_training_sim
from repro.sim.workload import OpSpec, ProgramSpec


def program_from_artifact(arch: str, shape: str, segments: int = 6) -> ProgramSpec:
    path = f"results/dryrun/{arch}.{shape}.16x16.json"
    ops = []
    if os.path.exists(path):
        rec = json.load(open(path))
        flops, hbm = rec["cost"]["flops"], rec["cost"]["bytes_accessed"]
        coll = [(k, v["bytes"] / max(v["count"], 1)) for k, v in
                rec["collectives"]["per_kind"].items() if v["count"]]
        print(f"program from {path}: {flops:.2e} FLOP/dev, "
              f"{rec['collectives']['total_bytes']:.2e} coll B/dev")
    else:
        flops, hbm, coll = 2e13, 5e11, [("all-gather", 5e7), ("all-reduce", 2e7)]
        print("no artifact found (run the dry-run first); using synthetic costs")
    # scale the demo to ~tens of virtual ms per step (proportions preserved)
    # so the simulated background-traffic event count stays tractable
    scale = min(1.0, 2e11 / max(flops, 1))
    flops, hbm = flops * scale, hbm * scale
    coll = [(k, avg * scale) for k, avg in coll]
    for s in range(segments):
        ops.append(OpSpec(f"seg{s}", "compute", flops / segments, hbm / segments))
        for kind, avg in coll:
            ops.append(OpSpec(f"{kind}.{s}", kind, coll_bytes=avg))
    ops.append(OpSpec("grad.sync", "all-reduce", coll_bytes=hbm / 128, group="dcn"))
    return ProgramSpec("train_step", ops)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--out", default="results/straggler")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    prog = program_from_artifact(args.arch, args.shape)
    cluster = run_training_sim(
        prog, n_steps=2, n_pods=2, chips_per_pod=4,
        outdir=os.path.join(args.out, "logs"),
        compute_scale={"pod1.chip01": 3.0},
        bg_traffic_link="dcn.h0h1", bg_rate=20e9,
    )
    session = TraceSession().attach(
        ChromeTraceExporter(os.path.join(args.out, "trace.chrome.json"))
    )
    for paths in cluster.log_paths().values():
        for p in paths:
            session.add_log(p)   # sim type auto-detected from the log tag
    spans = session.run()

    rep = straggler_report(spans, span_name="Op")
    print(f"\nstraggler report: flagged={rep['stragglers']}")
    for c, v in sorted(rep["per_component_us"].items()):
        mark = "  <-- straggler" if c in rep["stragglers"] else ""
        print(f"  {c:16s} median Op = {v:9.1f} us{mark}")

    dcn = [s for s in spans if s.name == "LinkTransfer" and s.component.startswith("dcn")]
    coll_dcn = [s for s in dcn if "coll" in s.attrs]
    if coll_dcn:
        q = sum(s.attrs.get("queue_ps", 0) for s in coll_dcn) / len(coll_dcn) / 1e6
        print(f"\ncross-pod grad-sync chunks: {len(coll_dcn)}, "
              f"mean queueing on contended DCN link = {q:.1f} us")
    print(f"\ntrace: {args.out}/trace.chrome.json (open in Perfetto)")


if __name__ == "__main__":
    main()
