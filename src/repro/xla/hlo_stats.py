"""Static analysis of compiled XLA artifacts.

Extracts the quantities the roofline analysis and the device simulator need:

* ``cost_summary(compiled)``      — HLO FLOPs + bytes from cost_analysis()
* ``collective_stats(hlo_text)``  — per-kind collective operand bytes parsed
                                    from the *optimized* (post-SPMD) HLO text
                                    (``compiled.as_text()``), since GSPMD
                                    inserts collectives only after partitioning.

Byte counts are **per-device** (an SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g.:  "... = bf16[8,128]{1,0} all-gather-start(bf16[8,16]{1,0} %p), ..."
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes appearing in ``shape_str``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        bpe = _DTYPE_BYTES.get(dtype)
        if bpe is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bpe
    return total


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Parse per-kind collective bytes from optimized (post-SPMD) HLO text.

    Optimized HLO omits operand shapes, so operand bytes are derived from the
    *result* shape and the replica group size N:

        all-reduce          operand = result
        all-gather          operand = result / N
        reduce-scatter      operand = result * N
        all-to-all          operand = result
        collective-permute  operand = result

    ``wire_bytes`` additionally models per-device bytes on the interconnect
    under ring algorithms: AR 2(N-1)/N * B_result, AG/RS (N-1)/N * B_full,
    A2A (N-1)/N * B, CP = B.  ``-done`` ops are skipped (async pairs would
    double-count); for ``-start`` tuples the last tuple element (the output
    buffer) is used.  All quantities are per device.
    """
    per_kind: Dict[str, Dict[str, int]] = {
        k: {"count": 0, "bytes": 0, "wire_bytes": 0} for k in COLLECTIVE_KINDS
    }
    ops: List[Dict[str, Any]] = []
    for line in hlo_text.splitlines():
        if "all-" not in line and "reduce-scatter" not in line and "collective-permute" not in line:
            continue
        if "-done" in line:
            continue
        # require "<name> = <shape(s)> <kind>(" form: search after the '='
        # (the instruction NAME itself contains the kind, e.g. %all-reduce.1)
        eq = line.find("=")
        if eq == -1:
            continue
        m = _COLL_RE.search(line, eq + 1)
        if m is None:
            continue
        kind = m.group(1)
        shapes = _SHAPE_RE.findall(line[eq + 1 : m.start()])
        if not shapes:
            continue
        dtype, dims = shapes[-1]  # last tuple element = output buffer
        bpe = _DTYPE_BYTES.get(dtype, 0)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        result_bytes = n * bpe
        gsize = _group_size(line)
        if kind == "all-gather":
            operand = result_bytes // max(gsize, 1)
            wire = int(result_bytes * (gsize - 1) / max(gsize, 1))
        elif kind == "reduce-scatter":
            operand = result_bytes * gsize
            wire = int(operand * (gsize - 1) / max(gsize, 1))
        elif kind == "all-reduce":
            operand = result_bytes
            wire = int(2 * result_bytes * (gsize - 1) / max(gsize, 1))
        elif kind == "all-to-all":
            operand = result_bytes
            wire = int(result_bytes * (gsize - 1) / max(gsize, 1))
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        per_kind[kind]["count"] += 1
        per_kind[kind]["bytes"] += operand
        per_kind[kind]["wire_bytes"] += wire
        name = line.strip().split(" ", 1)[0].lstrip("%")
        ops.append(
            {"name": name, "kind": kind, "bytes": operand, "wire_bytes": wire,
             "group_size": gsize, "async": bool(m.group(2))}
        )
    total = sum(v["bytes"] for v in per_kind.values())
    wire_total = sum(v["wire_bytes"] for v in per_kind.values())
    return {
        "per_kind": per_kind,
        "total_bytes": total,
        "wire_bytes": wire_total,
        "ops": ops,
    }


# ops that move HBM bytes on TPU even under aggressive fusion; pure
# elementwise ops (convert/add/mul/select/...) fuse into producers/consumers
# and are excluded — XLA:CPU leaves them unfused, which inflates
# cost_analysis()'s "bytes accessed" ~20-50x vs TPU behaviour.
_TRAFFIC_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "sort", "transpose", "copy", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "reverse", "custom-call",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
}

_OP_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?(%\S+) = (.+?) ([a-z][a-z0-9-]*)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


def tpu_adjusted_bytes(hlo_text: str) -> Dict[str, float]:
    """TPU-fusion-adjusted HBM bytes from optimized HLO text.

    Counts operand+output bytes of entry-computation instructions whose op
    kind is in _TRAFFIC_OPS (operand shapes resolved via the producing
    instruction's result shape).  Fusion-internal instructions are inside
    separate computations and therefore not double counted.
    """
    # name -> result bytes, for every instruction in the module
    sizes: Dict[str, int] = {}
    entry_lines: List[str] = []
    in_entry = False
    depth = 0
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.match(line)
        if m:
            name, shapes, op = m.groups()
            sizes[name] = parse_shape_bytes(shapes)
        stripped = line.strip()
        if stripped.startswith("ENTRY "):
            in_entry = True
            depth = 0
        if in_entry:
            depth += stripped.count("{") - stripped.count("}")
            if m:
                entry_lines.append(line)
            if depth <= 0 and "}" in stripped and not stripped.startswith("ENTRY"):
                in_entry = False

    total = 0
    per_kind: Dict[str, int] = {}
    for line in entry_lines:
        m = _OP_LINE_RE.match(line)
        if m is None:
            continue
        name, shapes, op = m.groups()
        base = op.split(".")[0]
        if base not in _TRAFFIC_OPS:
            continue
        out_b = sizes.get(name, 0)
        # operand bytes: resolve %names inside the call parens
        lparen = line.find("(", m.end(3) - 1)
        rparen = line.find("), ", lparen)
        seg = line[lparen: rparen if rparen != -1 else None]
        operands = [t for t in _OPERAND_RE.findall(seg) if t != name]
        op_b = sum(sizes.get(t, 0) for t in operands)
        if base == "dynamic-update-slice" and len(operands) >= 2:
            # in-place slice update (donated buffers alias): traffic is the
            # update slice written + read, not the whole buffer
            upd = sizes.get(operands[1], 0)
            out_b, op_b = upd, upd
        total += out_b + op_b
        per_kind[base] = per_kind.get(base, 0) + out_b + op_b
    return {"total": float(total), "per_kind": per_kind}


def cost_summary(compiled: Any) -> Dict[str, float]:
    """FLOPs / bytes-accessed from compiled.cost_analysis() (per device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    out = {"flops": flops, "bytes_accessed": bytes_accessed}
    # operand/output split if present
    for k, v in ca.items():
        if isinstance(v, (int, float)) and k.startswith("bytes accessed"):
            out[k] = float(v)
    return out


def memory_stats(compiled: Any) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out: Dict[str, float] = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_bytes"] = (
        out.get("argument_size_in_bytes", 0.0)
        + out.get("output_size_in_bytes", 0.0)
        + out.get("temp_size_in_bytes", 0.0)
        - out.get("alias_size_in_bytes", 0.0)
    )
    return out
