from .compat import shard_map
from .compression import (
    compressed_psum,
    dequantize_int8,
    ef_compress,
    quantize_int8,
    tree_compressed_psum,
    tree_ef_state,
)
from .pipeline import pipeline_apply

__all__ = [k for k in dir() if not k.startswith("_")]
