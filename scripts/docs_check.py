"""Documentation checks (run via scripts/docs_check.sh; part of tier-1).

Two failure classes, both cheap and deterministic:

1. **Broken intra-repo references** in README.md and docs/*.md:
   - markdown links ``[text](path)`` whose target is a repo path that does
     not exist (external http(s)/mailto links and pure #anchors are skipped);
   - ``[[file:line]]`` code anchors whose file is missing or whose line
     number exceeds the file's length.

2. **Code blocks that don't import**: every ```python fenced block must
   compile, and its top-level ``import``/``from`` statements must execute
   (doctest-style smoke with PYTHONPATH=src) — so the docs can't drift
   ahead of the API they document.  Full blocks are not executed: examples
   legitimately reference runtime artifacts (log files, clusters).
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
CODE_ANCHOR = re.compile(r"\[\[([^\]\s:]+):(\d+)\]\]")
FENCE = re.compile(r"^```(\w*)\s*$")


def _doc_files():
    out = [os.path.join(REPO, "README.md")]
    out.extend(sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))
    return [p for p in out if os.path.exists(p)]


def _strip_code_blocks(text: str) -> str:
    """Remove fenced blocks so link checks don't trip on code."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(path: str, text: str):
    errors = []
    base = os.path.dirname(path)
    prose = _strip_code_blocks(text)
    for target in MD_LINK.findall(prose):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        # resolve relative to the doc, then to the repo root
        if not (
            os.path.exists(os.path.join(base, rel))
            or os.path.exists(os.path.join(REPO, rel))
        ):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link -> {target}")
    for fname, line_s in CODE_ANCHOR.findall(text):
        fpath = os.path.join(REPO, fname)
        if not os.path.exists(fpath):
            errors.append(
                f"{os.path.relpath(path, REPO)}: anchor [[{fname}:{line_s}]] "
                f"-> file missing"
            )
            continue
        n_lines = sum(1 for _ in open(fpath, "rb"))
        if int(line_s) > n_lines:
            errors.append(
                f"{os.path.relpath(path, REPO)}: anchor [[{fname}:{line_s}]] "
                f"-> only {n_lines} lines"
            )
    return errors


def _python_blocks(text: str):
    blocks, cur, lang, start = [], None, None, 0
    for i, line in enumerate(text.splitlines(), 1):
        m = FENCE.match(line)
        if m and cur is None:
            lang, cur, start = m.group(1).lower(), [], i
        elif m:
            if lang == "python":
                blocks.append((start, "\n".join(cur)))
            cur, lang = None, None
        elif cur is not None:
            cur.append(line)
    return blocks


def check_code_blocks(path: str, text: str):
    import ast

    errors = []
    rel = os.path.relpath(path, REPO)
    for start, block in _python_blocks(text):
        try:
            tree = ast.parse(block, filename=f"{rel}:{start}")
        except SyntaxError as e:
            errors.append(f"{rel}:{start}: python block does not compile: {e}")
            continue
        imports = [
            node for node in tree.body if isinstance(node, (ast.Import, ast.ImportFrom))
        ]
        if not imports:
            continue
        src = "\n".join(ast.unparse(node) for node in imports)
        try:
            exec(compile(src, f"{rel}:{start}<imports>", "exec"),
                 {"__name__": f"docs_check_{start}"})
        except Exception as e:  # noqa: BLE001 - any import failure is a doc bug
            errors.append(f"{rel}:{start}: doc imports fail: {type(e).__name__}: {e}")
    return errors


def main() -> int:
    sys.path.insert(0, os.path.join(REPO, "src"))
    errors = []
    for path in _doc_files():
        text = open(path).read()
        errors.extend(check_links(path, text))
        errors.extend(check_code_blocks(path, text))
    if errors:
        print("docs_check: FAILED")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs_check: OK ({len(_doc_files())} docs checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
