"""Benchmark harness: one module per paper table/figure + framework perf.

Prints ``name,us_per_call,derived`` CSV rows (one per measurement).

  smoke      — CI pre-flight: tiny sim -> TraceSpec weave -> invariants
  table1     — §4 Table 1: event/span type inventory per simulator type
  fig4_fig5  — §5 Fig. 4/5: clock skew + chrony estimates, both scenarios
  fig6       — §5 Fig. 6: per-component breakdown (+ straggler analogue)
  pipeline   — §3.5: log->span processing throughput
  online     — §3.8: named-pipe online mode
  roofline   — §Roofline terms per (arch x shape) from dry-run artifacts
  scenarios  — fault-injection loop: inject -> simulate -> weave -> diagnose
  engine     — DES kernel + sweep perf (smoke sizes; full run:
               ``python -m benchmarks.engine_bench``)
"""
import sys
import time
import traceback


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from . import (
        engine_bench,
        fig4_fig5_clock_sync,
        fig6_breakdown,
        online_mode,
        pipeline_tput,
        roofline,
        scenario_sweep,
        smoke,
        table1_coverage,
    )

    benches = {
        "smoke": smoke.run,
        "table1": table1_coverage.run,
        "fig4_fig5": fig4_fig5_clock_sync.run,
        "fig6": fig6_breakdown.run,
        "pipeline": pipeline_tput.run,
        "online": online_mode.run,
        "roofline": roofline.run,
        "scenarios": scenario_sweep.run,
        "engine": engine_bench.run,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        if only and name != only:
            continue
        try:
            for row in fn():
                n, us, d = row
                print(f"{n},{us:.1f},{d}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
