"""jax API compatibility shims for the distributed layer.

``shard_map`` moved from ``jax.experimental.shard_map`` (where replication
checking is the ``check_rep`` kwarg) to top-level ``jax.shard_map`` (where
it became ``check_vma``).  :func:`shard_map` here presents the new-style
surface on either jax, so callers write one spelling:

    from repro.distributed.compat import shard_map
    f = shard_map(fn, mesh=mesh, in_specs=..., out_specs=..., check_vma=False)
"""
from __future__ import annotations

import jax

try:
    _TOP_LEVEL_SHARD_MAP = jax.shard_map
except AttributeError:        # jax < 0.6: only the experimental spelling exists
    _TOP_LEVEL_SHARD_MAP = None


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on any supported jax.

    On older jax the call lowers to ``jax.experimental.shard_map.shard_map``
    with ``check_vma`` mapped onto its ``check_rep`` predecessor.
    """
    if _TOP_LEVEL_SHARD_MAP is not None:
        return _TOP_LEVEL_SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _experimental

    return _experimental(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
