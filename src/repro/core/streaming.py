"""Incremental in-sim span weaving (the ``weave="inline"`` path).

Columbo's post-hoc pipeline pays format -> parse -> weave after the
simulation finishes; the structured fast path drops format/parse but still
replays every captured record through the weavers in a separate pass.  The
:class:`StreamingWeaver` goes the last step: the cluster's log writers feed
it records *as the kernel executes* (see ``sim/clock.InlineWeaveWriter``),
and it dispatches them straight into the existing :class:`SpanWeaver`
handlers (same dict-dispatch tables, same :class:`ContextRegistry`) — by
the time the simulation drains, the spans are already woven.

Byte-identity with the post-hoc paths is the repo's reproducibility
contract, and it is non-trivial here: the post-hoc weave consumes *all*
host events, then all device events, then all net events (sync-priority
order), allocating span/trace ids in exactly that order, while the inline
weave sees the same events interleaved in virtual-time order.  Two
mechanisms close the gap:

* **watermark batches** — records buffer per simulator type and flush in
  sync-priority order whenever the kernel's clock advances (every record
  is stamped ``kernel.now``, so timestamps are globally nondecreasing).
  Within one timestamp this reproduces the post-hoc type order
  (host -> device -> net) and, via a stable sort on writer index, the
  per-type shard-merge tie-break (``MergedProducer``: equal timestamps go
  to the earlier-created writer).
* **tagged id spaces** — each simulator type allocates span/trace ids from
  its own counter in a disjoint tagged range (``tag << 44``).  At finish,
  deferred contexts resolve first (they traffic in tagged ids), then a
  remap pass renumbers every id into exactly what the sequential post-hoc
  weave would have allocated (host block first, then device, then net),
  then trace ids unify through the parent graph — the same two post-weave
  steps as :func:`finalize_spans`, with the remap spliced between them.

Everything else — handlers, context keys, deferred resolution, the final
``(trace_id, start, span_id)`` sort, SpanJSONL encoding — is shared code,
which is what makes the byte-for-byte guarantee testable rather than
aspirational (``tests/test_streaming_weave.py``).
"""
from __future__ import annotations

import gc as _gc
import itertools
from operator import itemgetter
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import span as _span
from .context import ContextRegistry, UnlockedContextRegistry
from .events import sim_type_value
from .parsers import (
    _NUM_LEAD,
    DEVICE_NAME_TO_CLASS,
    HOST_KIND_TO_CLASS,
    NET_MARK_TO_CLASS,
    _coerce,
    coerce_value,
)
from .span import Span, SpanContext
from .weaver import SpanWeaver

try:  # columnar final sort; pure-python fallback stays byte-identical
    import numpy as _np
except ModuleNotFoundError:  # pragma: no cover - minimal installs
    _np = None

__all__ = ["StreamingWeaver", "InlineTraceSession", "WovenColumns"]

# Tagged id ranges: ordinals are dense per type, the tag keeps the three
# in-flight id spaces disjoint until the finish-time remap.  44 bits leaves
# room for ~17.6e12 ids per type — far beyond any simulation this kernel
# can drain — while tagged values still fit comfortably in an int64 (the
# columnar sort path).
_TAG_BITS = 44
_TAG_STRIDE = 1 << _TAG_BITS
_TAG_MASK = _TAG_STRIDE - 1

# The builtin trio's post-hoc processing order (sync priority: host=0 <
# device=10 < net=20).  host MUST be tag 0: untagged ids (including the
# span_id=0 sentinels some registry keys carry) remap with offset 0, and
# the sequential weave allocates the host block first anyway.
_TYPE_TAG = {"host": 0, "device": 1, "net": 2}

_ITEM0 = itemgetter(0)


class _EventShim:
    """Reusable event stand-in for record-level dispatch.

    Weaver handlers only read ``ev.ts`` / ``ev.source`` / ``ev.attrs``
    (and ``ev.kind`` in the late-event path), so the drain loops reuse one
    mutable shim per simulator type instead of materializing an Event
    object per record.  ``kind`` holds the record's dispatch key (host
    kind, device event-class name, or net mark)."""

    __slots__ = ("ts", "source", "kind", "attrs")


class _NetColumnsBuilder:
    """Growable column builders for the fused columnar net weave.

    One row per LinkTransfer (the ``"+"`` mark); ids are implicit — the
    fused net emit allocates trace and span ordinals in lockstep, so row
    ``i`` owns both span ordinal ``i + 1`` and trace ordinal ``i + 1`` in
    the tagged net id space.  ``metas`` stores the per-transfer meta dicts
    by reference (``netsim._Transfer`` never mutates them after emit);
    attr coercion is deferred to render/materialize time, off the hot
    path.  ``xorders`` records the first-occurrence order of the extra
    attrs (``'q'`` = queue_ps, ``'d'`` = drops) so rendered dict order
    matches the object path's insertion order exactly."""

    __slots__ = ("starts", "ends", "comp_codes", "comp_pool", "comp_index",
                 "chunks", "sizes", "metas", "queues", "drops", "nevs",
                 "xorders", "pkeys", "events", "open", "unclosed")

    def __init__(self) -> None:
        self.starts: List[int] = []
        self.ends: List[int] = []
        self.comp_codes: List[int] = []      # row -> index into comp_pool
        self.comp_pool: List[str] = []       # link-name string pool
        self.comp_index: Dict[str, int] = {}
        self.chunks: List[Any] = []
        self.sizes: List[Any] = []
        self.metas: List[dict] = []
        self.queues: List[int] = []          # last wire_tx ts - start
        self.drops: List[int] = []
        self.nevs: List[int] = []            # wire_tx + chunk_drop count
        self.xorders: List[str] = []         # '' | 'q' | 'd' | 'qd' | 'dq'
        self.pkeys: List[Optional[tuple]] = []   # deferred parent key
        self.events: List[tuple] = []        # flat (row, ts, kind, size, meta)
        self.open: Dict[Tuple[str, Any], int] = {}
        self.unclosed: frozenset = frozenset()

    def __len__(self) -> int:
        return len(self.starts)


class StreamingWeaver:
    """Weaves spans *during* the simulation from per-event records.

    The cluster's inline log writers call :meth:`attach` once per writer
    and feed every record to the returned emit callable; :meth:`finish`
    runs the post-weave steps (deferred resolution, id remap, trace-id
    unification, canonical sort) and returns spans byte-equivalent to the
    post-hoc weave of the same simulation.

    ``live_exporters`` optionally receive each span the moment its weaver
    completes it (mid-simulation, completion order, provisional pre-remap
    ids) — a monitoring tap with the same fan-out isolation as
    ``TraceSession.export``; the byte-identical artifact is produced by
    exporting the finished spans.

    ``columnar=True`` switches the net stream — the dominant record class
    (every link hop is 3-4 records, ~85% of all spans at fleet scale) —
    to a column-building emit that appends span fields straight into
    parallel arrays and never materializes a ``Span`` object on the hot
    path.  :meth:`finish_columns` then resolves, renumbers, and orders the
    whole run with vectorized passes and returns a :class:`WovenColumns`
    whose JSONL rendering (``core.exporters.render_woven_jsonl``) is
    byte-identical to exporting the object-path spans.  ``Span`` objects
    are still available lazily via ``WovenColumns.to_spans()`` for
    graph-walking consumers (diagnose, Chrome export).
    """

    def __init__(
        self,
        simulators=None,
        registry: Optional[ContextRegistry] = None,
        poll_timeout: float = 0.0,
        columnar: bool = False,
    ) -> None:
        if simulators is None:
            from .registry import DEFAULT_REGISTRY

            simulators = DEFAULT_REGISTRY
        self.simulators = simulators
        # inline weaving is strictly single-threaded (records arrive from
        # the kernel's drain loop), so the unlocked registry is safe
        self.context = registry if registry is not None else UnlockedContextRegistry()
        self.poll_timeout = poll_timeout
        self.weavers: Dict[str, SpanWeaver] = {}
        self.events_in: Dict[str, int] = {}
        self.spans: Optional[List[Span]] = None
        self.finalize_stats: Dict[str, int] = {}
        self.live_exporters: List[Any] = []
        self.live_errors: List[Exception] = []
        self._live_failed: set = set()
        self._tap_installed = False
        self._wm: List[int] = [-1]          # watermark cell shared by emits
        self._batches: Dict[str, List[tuple]] = {}
        self._drains: List[Tuple[List[tuple], Callable[[List[tuple]], None]]] = []
        self._span_ctrs: Dict[str, Any] = {}
        self._trace_ctrs: Dict[str, Any] = {}
        self._writer_counts: Dict[str, int] = {}
        self._net_emit: Optional[Callable[[tuple], None]] = None
        self._net_xfer: Dict[Tuple[str, Any], Span] = {}
        self._net_count = [0]               # mutable cell: fused-path events_in
        self._columns = None                # cached SpanColumns of finished spans
        self._finished = False
        self.columnar = bool(columnar)
        self._net_builder: Optional[_NetColumnsBuilder] = None
        self._woven: Optional["WovenColumns"] = None

    # -- capture side (what InlineWeaveWriter binds) ---------------------------

    def attach(self, sim_type) -> Callable[[tuple], None]:
        """Register one log writer of ``sim_type``; returns its emit.

        Writers of one type are ranked by attach order — the same
        creation-order rank ``MergedProducer`` uses to tie-break equal
        timestamps in the post-hoc shard merge."""
        st = sim_type_value(sim_type)
        if self._finished:
            raise RuntimeError("StreamingWeaver already finished; cannot attach")
        tag = _TYPE_TAG.get(st)
        if tag is None:
            raise ValueError(
                f"inline weaving supports the builtin simulator types "
                f"{sorted(_TYPE_TAG)}, not {st!r}; use the post-hoc paths "
                f"for custom types"
            )
        if st not in self.weavers:
            w = self.simulators.make_weaver(st, self.context, poll_timeout=self.poll_timeout)
            # interleaved arrival order must not leak into context lookups:
            # defer them all to finish, where the registry holds the same
            # final state the sequential weave's eager polls observed
            w.defer_polls = True
            self.weavers[st] = w
            if self._tap_installed:
                self._wrap_emit(w)
            self.events_in[st] = 0
            self._span_ctrs[st] = itertools.count(tag * _TAG_STRIDE + 1)
            self._trace_ctrs[st] = itertools.count(tag * _TAG_STRIDE + 1)
            self._writer_counts[st] = 0
            if st == "net":
                # net records dominate the stream (every link hop is 3-4
                # records) but under defer_polls the net weaver never reads
                # the registry — it only defers and pushes — and the net
                # stream is single-writer, so its records need neither the
                # watermark buffer nor the MergedProducer tie-break: a
                # fused handler weaves each record the moment it is emitted
                if self.columnar:
                    self._net_builder = _NetColumnsBuilder()
                    self._net_emit = self._make_net_emit_columnar(w)
                else:
                    self._net_emit = self._make_net_emit(w)
            else:
                batch: List[tuple] = []
                self._batches[st] = batch
                self._drains.append((batch, self._make_drain(st, w)))
                self._drains.sort(key=lambda bd: _TYPE_TAG[bd[1].sim_type])
        idx = self._writer_counts[st]
        self._writer_counts[st] = idx + 1
        if st == "net":
            if idx > 0:
                raise RuntimeError(
                    "inline weaving supports a single net log writer (the "
                    "cluster creates exactly one); multi-writer net streams "
                    "need the post-hoc shard merge"
                )
            return self._net_emit
        append = self._batches[st].append
        wm = self._wm
        advance = self._advance

        def emit(rec, _append=append, _idx=idx, _wm=wm, _advance=advance):
            if rec[0] != _wm[0]:
                _advance(rec[0])
            _append((_idx, rec))

        return emit

    def _advance(self, ts: int) -> None:
        wm = self._wm
        if ts < wm[0]:
            raise RuntimeError(
                f"inline weave saw a record timestamp go backwards "
                f"({ts} < {wm[0]}); records must be emitted at kernel.now"
            )
        for batch, drain in self._drains:
            if batch:
                drain(batch)
                del batch[:]
        wm[0] = ts

    # -- record dispatch -------------------------------------------------------

    def _make_drain(self, st: str, w: SpanWeaver) -> Callable[[List[tuple]], None]:
        """One drain closure per host/device weaver: sorts multi-writer
        batches by writer rank (stable — the MergedProducer tie-break),
        replicates ``StructuredLogWriter.events()``'s attr coercion
        exactly, and dict-dispatches into the weaver's existing handlers
        through a reusable shim.  Swaps the type's tagged id counters into
        the span module for the duration (handlers allocate ids via the
        module-level ``new_span_id``/``new_trace_id``).  Net records never
        reach a drain — see :meth:`_make_net_emit`."""
        handlers = w._handlers
        table = HOST_KIND_TO_CLASS if st == "host" else DEVICE_NAME_TO_CLASS
        disp: Dict[str, Callable] = {}
        registered_unhandled = set()
        for key, cls in table.items():
            h = handlers.get(cls.kind)
            if h is None:
                # registered event class without a handler: the post-hoc
                # weave counts it unhandled; unknown keys are dropped like
                # events() drops records with no registered class
                registered_unhandled.add(key)
            else:
                disp[key] = h
        shim = _EventShim()
        span_ctr = self._span_ctrs[st]
        trace_ctr = self._trace_ctrs[st]
        counts = self.events_in
        writer_counts = self._writer_counts

        def drain(batch, _get=disp.get, _unh=registered_unhandled,
                  _shim=shim, _coerce=coerce_value):
            _span._span_counter = span_ctr
            _span._trace_counter = trace_ctr
            if writer_counts[st] > 1 and len(batch) > 1:
                batch.sort(key=_ITEM0)
            counts[st] += len(batch)
            unhandled = 0
            for _i, rec in batch:
                ts, source, kind, attrs = rec
                h = _get(kind)
                if h is None:
                    if kind in _unh:
                        unhandled += 1
                    continue
                coerced = None
                for k, v in attrs.items():
                    if type(v) is not int:
                        cv = _coerce(v)
                        if cv is not v:
                            if coerced is None:
                                coerced = dict(attrs)
                            coerced[k] = cv
                _shim.ts = ts
                _shim.source = source
                _shim.kind = kind
                _shim.attrs = attrs if coerced is None else coerced
                h(_shim)
            if unhandled:
                w.unhandled_events += unhandled

        drain.sim_type = st
        return drain

    def _make_net_emit(self, w: SpanWeaver) -> Callable[[tuple], None]:
        """Fused net weave: one closure replicating ``NetSpanWeaver``'s
        enqueue/tx/drop/rx handlers (plus ``events()``'s attr coercion and
        ``_begin``'s span construction) so each net record is woven in a
        single call — no batch, no shim, no dict dispatch, no module
        counter swap.  Safe because under ``defer_polls`` the net weaver
        never *reads* the registry (parents defer, link_span contexts are
        only consumed by finish-time deferred resolution) and the net
        stream has one writer, so emit order IS the canonical net event
        order.  Byte-identity is asserted by the same golden harness as
        the general path."""
        xfer = self._net_xfer
        cell = self._net_count
        reg = self.context
        defer = reg.defer
        push = reg.push
        spans_append = w.spans.append
        stc = w.span_type_counts
        shim = _EventShim()
        shim.attrs = {}
        sw = self

        def emit(rec, _cv=coerce_value, _NUM=_NUM_LEAD, _SC=SpanContext,
                 _Span=Span,
                 _next_t=self._trace_ctrs["net"].__next__,
                 _next_s=self._span_ctrs["net"].__next__,
                 _xget=xfer.get, _xpop=xfer.pop, _late=w._late):
            ts, mark, link, chunk, size, meta = rec
            if mark == "r":
                cell[0] += 1
                span = _xpop((link, chunk), None)
                if span is None:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_rx"
                    _late(shim)
                    return
                if ts > span.start:
                    span.end = ts
                spans_append(span)
                if sw._tap_installed:
                    sw._tap(span)
            elif mark == "+":
                cell[0] += 1
                attrs = {"chunk": chunk, "size": size}
                # the inline-expanded _NUM_LEAD gate of coerce_value: ints
                # and identifier-shaped strings (the vast majority) pass
                # through without a function call
                for k, v in meta.items():
                    t = type(v)
                    if t is int or (t is str and (not v or v[0] not in _NUM)):
                        attrs[k] = v
                    else:
                        attrs[k] = _cv(v)
                span = _Span(name="LinkTransfer", start=ts, end=ts,
                             context=_SC(_next_t(), _next_s()),
                             component=link, sim_type="net", attrs=attrs)
                # same natural-boundary key selection as _on_chunk_enqueue
                if "dma" in attrs:
                    defer(span, ("h2d", attrs["dma"]), mode="parent")
                elif attrs.get("proto") == "ntp":
                    defer(span, ("ntp", attrs.get("peer"), attrs.get("seq")), mode="parent")
                elif "rpc" in attrs:
                    defer(span, ("rpccall", attrs["rpc"]), mode="parent")
                elif "flow" not in attrs:
                    defer(span, ("chunk", chunk), mode="parent")
                push(("link_span", chunk), span.context)
                xfer[(link, chunk)] = span
            elif mark == "-":
                cell[0] += 1
                span = _xget((link, chunk))
                if span is None:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_tx"
                    _late(shim)
                    return
                attrs = {"chunk": chunk, "size": size}
                for k, v in meta.items():
                    t = type(v)
                    if t is int or (t is str and (not v or v[0] not in _NUM)):
                        attrs[k] = v
                    else:
                        attrs[k] = _cv(v)
                span.events.append((ts, "wire_tx", attrs))
                span.attrs["queue_ps"] = ts - span.start
            elif mark == "d":
                cell[0] += 1
                span = _xget((link, chunk))
                if span is None:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_drop"
                    _late(shim)
                    return
                attrs = {"chunk": chunk, "size": size}
                for k, v in meta.items():
                    t = type(v)
                    if t is int or (t is str and (not v or v[0] not in _NUM)):
                        attrs[k] = v
                    else:
                        attrs[k] = _cv(v)
                span.events.append((ts, "chunk_drop", attrs))
                a = span.attrs
                a["drops"] = int(a.get("drops", 0)) + 1
            # unknown marks: dropped, like events() drops unregistered records

        return emit

    def _make_net_emit_columnar(self, w: SpanWeaver) -> Callable[[tuple], None]:
        """Columnar twin of :meth:`_make_net_emit`: each net record appends
        raw fields into the :class:`_NetColumnsBuilder` arrays — no Span,
        no attrs dict, no id allocation (row position IS the ordinal in the
        tagged net id space).  The registry still sees the same traffic as
        the object path — a ``("link_span", chunk)`` push per transfer (the
        device collective weaver links against it) — but parent deferral is
        reduced to recording the natural-boundary key; resolution happens
        vectorized in :meth:`finish_columns`.  Attr coercion is applied
        only to the deferred-key values here (they must match the pushing
        side's coerced attrs); everything else coerces at render time."""
        nb = self._net_builder
        cell = self._net_count
        push = self.context.push
        starts = nb.starts
        ends = nb.ends
        queues = nb.queues
        drops = nb.drops
        nevs = nb.nevs
        xorders = nb.xorders
        comp_index = nb.comp_index
        comp_pool = nb.comp_pool
        open_map = nb.open
        shim = _EventShim()
        shim.attrs = {}
        a_start = starts.append
        a_end = ends.append
        a_code = nb.comp_codes.append
        a_chunk = nb.chunks.append
        a_size = nb.sizes.append
        a_meta = nb.metas.append
        a_queue = queues.append
        a_drop = drops.append
        a_nev = nevs.append
        a_xord = xorders.append
        a_pkey = nb.pkeys.append
        a_ev = nb.events.append
        base = _TYPE_TAG["net"] * _TAG_STRIDE + 1

        def emit(rec, _cv=coerce_value, _NUM=_NUM_LEAD, _SC=SpanContext,
                 _oget=open_map.get, _opop=open_map.pop, _late=w._late):
            ts, mark, link, chunk, size, meta = rec
            if mark == "r":
                cell[0] += 1
                row = _opop((link, chunk), -1)
                if row < 0:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_rx"
                    _late(shim)
                    return
                if ts > starts[row]:
                    ends[row] = ts
            elif mark == "+":
                cell[0] += 1
                row = len(starts)
                a_start(ts)
                a_end(ts)
                code = comp_index.get(link)
                if code is None:
                    code = comp_index[link] = len(comp_pool)
                    comp_pool.append(link)
                a_code(code)
                a_chunk(chunk)
                a_size(size)
                a_meta(meta)
                a_queue(0)
                a_drop(0)
                a_nev(0)
                a_xord("")
                # same natural-boundary key selection as _on_chunk_enqueue;
                # key values go through the same coerce_value gate the
                # object path's coerced attrs dict would apply
                if "dma" in meta:
                    v = meta["dma"]
                    t = type(v)
                    if not (t is int or (t is str and (not v or v[0] not in _NUM))):
                        v = _cv(v)
                    key = ("h2d", v)
                elif meta.get("proto") == "ntp":
                    p = meta.get("peer")
                    t = type(p)
                    if not (p is None or t is int or (t is str and (not p or p[0] not in _NUM))):
                        p = _cv(p)
                    q = meta.get("seq")
                    t = type(q)
                    if not (q is None or t is int or (t is str and (not q or q[0] not in _NUM))):
                        q = _cv(q)
                    key = ("ntp", p, q)
                elif "rpc" in meta:
                    v = meta["rpc"]
                    t = type(v)
                    if not (t is int or (t is str and (not v or v[0] not in _NUM))):
                        v = _cv(v)
                    key = ("rpccall", v)
                elif "flow" not in meta:
                    key = ("chunk", chunk)
                else:
                    key = None
                a_pkey(key)
                rid = base + row
                push(("link_span", chunk), _SC(rid, rid))
                open_map[(link, chunk)] = row
            elif mark == "-":
                cell[0] += 1
                row = _oget((link, chunk), -1)
                if row < 0:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_tx"
                    _late(shim)
                    return
                nevs[row] += 1
                queues[row] = ts - starts[row]
                x = xorders[row]
                if "q" not in x:
                    xorders[row] = x + "q"
                a_ev((row, ts, "wire_tx", size, meta))
            elif mark == "d":
                cell[0] += 1
                row = _oget((link, chunk), -1)
                if row < 0:
                    shim.ts = ts
                    shim.source = link
                    shim.kind = "chunk_drop"
                    _late(shim)
                    return
                nevs[row] += 1
                drops[row] += 1
                x = xorders[row]
                if "d" not in x:
                    xorders[row] = x + "d"
                a_ev((row, ts, "chunk_drop", size, meta))
            # unknown marks: dropped, like events() drops unregistered records

        return emit

    # -- live exporter tap -----------------------------------------------------

    def add_live_exporter(self, exporter) -> None:
        """Attach an exporter receiving each span the moment its weaver
        completes it, while the simulation is still running.

        Spans arrive in completion order with provisional (pre-remap) ids:
        this is a streaming/monitoring tap, not the byte-identical
        artifact.  Exporters are isolated exactly like
        ``TraceSession.export``: one raising mid-stream is disabled (its
        ``finish()`` still runs so partial output flushes), the others keep
        receiving, and the first error re-raises from :meth:`finish`."""
        if self.columnar:
            raise RuntimeError(
                "live exporters need per-span objects the moment they "
                "complete; the columnar emit path never materializes them "
                "— use StreamingWeaver(columnar=False) for a live tap"
            )
        try:
            exporter.begin()
        except Exception as ex:
            self.live_errors.append(ex)
            self._live_failed.add(id(exporter))
        self.live_exporters.append(exporter)
        if not self._tap_installed:
            self._tap_installed = True
            for w in self.weavers.values():
                self._wrap_emit(w)

    def _wrap_emit(self, w: SpanWeaver) -> None:
        orig = w.emit

        def emit(span, _orig=orig, _tap=self._tap):
            _orig(span)
            _tap(span)

        w.emit = emit

    def _tap(self, span: Span) -> None:
        for e in self.live_exporters:
            if id(e) in self._live_failed:
                continue
            try:
                e.consume(span)
            except Exception as ex:
                self.live_errors.append(ex)
                self._live_failed.add(id(e))

    # -- finish: the post-weave steps ------------------------------------------

    def finish(self) -> List[Span]:
        """Flush, resolve, renumber, unify, sort — then the spans are
        exactly what ``ExecutionEngine.execute`` would have produced.

        In columnar mode this finishes the columns first and then
        materializes Span objects from them (lazily cached): callers that
        only consume the columns/JSONL never pay for this."""
        if self.columnar:
            return self.finish_columns().to_spans()
        if self._finished:
            return self.spans or []
        self._finished = True
        # same rationale as EventKernel.run(gc_pause=True): the span graph
        # is millions of live objects and this method allocates no cycles,
        # so letting gen-2 collections walk it mid-finish only burns time
        paused = _gc.isenabled()
        if paused:
            _gc.disable()
        try:
            return self._finish()
        finally:
            if paused:
                _gc.enable()

    def _finish(self) -> List[Span]:
        for batch, drain in self._drains:
            if batch:
                drain(batch)
                del batch[:]
        order = sorted(self.weavers, key=_TYPE_TAG.__getitem__)
        for st in order:
            # counters stay swapped in per type in case a handler's
            # on_finish ever allocates (none do today)
            _span._span_counter = self._span_ctrs[st]
            _span._trace_counter = self._trace_ctrs[st]
            if st == "net":
                # the fused net path keeps its own open-transfer dict; this
                # is NetSpanWeaver.on_finish's unclosed flush, verbatim
                w = self.weavers[st]
                self._fold_net_counts(w)
                for span in self._net_xfer.values():
                    span.attrs["unclosed"] = True
                    w.emit(span)
                self._net_xfer.clear()
            self.weavers[st].on_finish()

        # per-type allocation counts -> the post-hoc block offsets
        span_off = [0, 0, 0]
        trace_off = [0, 0, 0]
        cum_s = 0
        cum_t = 0
        for st, tag in _TYPE_TAG.items():
            span_off[tag] = cum_s
            trace_off[tag] = cum_t
            if st in self.weavers:
                base = tag * _TAG_STRIDE + 1
                cum_s += next(self._span_ctrs[st]) - base
                cum_t += next(self._trace_ctrs[st]) - base

        # 1. deferred resolution first: it assigns stored (tagged) contexts
        #    as parents and rebuilds span contexts from them, so remapping
        #    earlier would let resolution re-introduce tagged ids
        stats = self.context.resolve_deferred()
        spans: List[Span] = []
        for st in order:
            spans.extend(self.weavers[st].spans)
        # 2. + 3. renumber into the sequential weave's dense id blocks and
        #    unify trace ids through the parent graph — one fused rewrite
        _remap_and_unify(spans, span_off, trace_off)
        # 4. the same canonical ordering the post-hoc engine emits
        _sort_spans(spans)

        # leave the module counters where the sequential weave would have:
        # continuing after the last allocated id
        _span._span_counter = itertools.count(cum_s + 1)
        _span._trace_counter = itertools.count(cum_t + 1)

        self.finalize_stats = stats
        self.spans = spans
        for e in self.live_exporters:
            try:
                e.finish()
            except Exception as ex:
                if id(e) not in self._live_failed:
                    self.live_errors.append(ex)
                    self._live_failed.add(id(e))
        if self.live_errors:
            raise self.live_errors[0]
        return spans

    def finish_columns(self) -> "WovenColumns":
        """Columnar finish: flush, resolve, renumber, order — without ever
        building the net Span objects.  Returns the cached
        :class:`WovenColumns`; only valid in ``columnar=True`` mode."""
        if not self.columnar:
            raise RuntimeError(
                "finish_columns() requires StreamingWeaver(columnar=True); "
                "the object-path weaver finishes via finish()"
            )
        if self._woven is not None:
            return self._woven
        self._finished = True
        paused = _gc.isenabled()
        if paused:
            _gc.disable()
        try:
            self._woven = self._finish_columnar()
        finally:
            if paused:
                _gc.enable()
        return self._woven

    def _finish_columnar(self) -> "WovenColumns":
        for batch, drain in self._drains:
            if batch:
                drain(batch)
                del batch[:]
        nb = self._net_builder if self._net_builder is not None else _NetColumnsBuilder()
        n_net = len(nb)
        order_types = sorted(self.weavers, key=_TYPE_TAG.__getitem__)
        for st in order_types:
            _span._span_counter = self._span_ctrs[st]
            _span._trace_counter = self._trace_ctrs[st]
            if st == "net":
                # the columnar twin of the unclosed flush: rows still open
                # at drain get the trailing "unclosed" attr at render time
                w = self.weavers[st]
                self._fold_net_counts(w)
                nb.unclosed = frozenset(nb.open.values())
                nb.open.clear()
            self.weavers[st].on_finish()

        # per-type allocation counts -> the post-hoc block offsets; the
        # columnar net stream allocated nothing — its row count IS both
        # its span and trace count (ordinals advance in lockstep at "+")
        span_off = [0, 0, 0]
        trace_off = [0, 0, 0]
        cum_s = 0
        cum_t = 0
        for st, tag in _TYPE_TAG.items():
            span_off[tag] = cum_s
            trace_off[tag] = cum_t
            if st in self.weavers:
                if st == "net":
                    cum_s += n_net
                    cum_t += n_net
                else:
                    base = tag * _TAG_STRIDE + 1
                    cum_s += next(self._span_ctrs[st]) - base
                    cum_t += next(self._trace_ctrs[st]) - base

        # 1. object-side deferred resolution (device link_spans resolve
        #    against the contexts the columnar emit pushed)
        stats = self.context.resolve_deferred()
        obj_spans: List[Span] = []
        for st in order_types:
            obj_spans.extend(self.weavers[st].spans)
        # 2.+3. remap/unify the object spans; the returned root map is the
        #    parent-graph trace resolution the net rows join against
        root = _remap_and_unify(obj_spans, span_off, trace_off)
        _sort_spans(obj_spans)

        # 4. vectorizable net-row resolution.  resolve_deferred's
        #    mode="parent" semantics, specialized to the leaf position net
        #    rows occupy in the parent graph (nothing ever defers *on* a
        #    net row): a resolved row adopts its parent's unified trace
        #    (root of the parent chain, like the object path's adopt-then-
        #    remap), an orphaned or undeferred row keeps its own tagged
        #    trace remapped into the net block.  Keys repeat heavily
        #    (one push covers every hop of a transfer), so resolution
        #    memoizes per key while hit/miss counters stay per row.
        reg = self.context
        store = reg._store
        MASK = _TAG_MASK
        BITS = _TAG_BITS
        net_s0 = span_off[2]
        net_t0 = trace_off[2]
        tids = [0] * n_net
        psids = [-1] * n_net
        resolved = 0
        orphans = 0
        memo: Dict[tuple, Tuple[int, int]] = {}
        mget = memo.get
        sget = store.get
        rget = root.get
        for i, key in enumerate(nb.pkeys):
            if key is None:
                tids[i] = net_t0 + i + 1
                continue
            hit = mget(key)
            if hit is None:
                ctx = sget(key)
                if ctx is None:
                    hit = memo[key] = (-1, 0)
                else:
                    psid = ctx.span_id
                    r = rget(psid)
                    if r is None:
                        r = ctx.trace_id   # parent never woven: remap-only
                    hit = memo[key] = (
                        (psid & MASK) + span_off[psid >> BITS],
                        (r & MASK) + trace_off[r >> BITS],
                    )
            pf, tf = hit
            if pf < 0:
                orphans += 1
                tids[i] = net_t0 + i + 1
            else:
                resolved += 1
                psids[i] = pf
                tids[i] = tf
        reg.hits += resolved
        reg.misses += orphans
        stats = {"resolved": stats.get("resolved", 0) + resolved,
                 "orphans": stats.get("orphans", 0) + orphans}

        # 5. one merged canonical (trace_id, start, span_id) order over
        #    object spans (indices 0..m-1, already sorted) and net rows
        #    (indices m..m+n-1); span ids are unique so the key is total
        m = len(obj_spans)
        if _np is not None:
            tid_all = _np.empty(m + n_net, dtype=_np.int64)
            start_all = _np.empty(m + n_net, dtype=_np.int64)
            sid_all = _np.empty(m + n_net, dtype=_np.int64)
            for i, s in enumerate(obj_spans):
                ctx = s.context
                tid_all[i] = ctx.trace_id
                start_all[i] = s.start
                sid_all[i] = ctx.span_id
            if n_net:
                tid_all[m:] = tids
                start_all[m:] = nb.starts
                sid_all[m:] = _np.arange(net_s0 + 1, net_s0 + n_net + 1,
                                         dtype=_np.int64)
            merge_order = _np.lexsort((sid_all, start_all, tid_all))
        else:  # pragma: no cover - minimal installs
            keyed = [(s.context.trace_id, s.start, s.context.span_id, i)
                     for i, s in enumerate(obj_spans)]
            keyed.extend(
                (tids[i], nb.starts[i], net_s0 + i + 1, m + i)
                for i in range(n_net)
            )
            keyed.sort()
            merge_order = [k[3] for k in keyed]

        # leave the module counters where the sequential weave would have
        _span._span_counter = itertools.count(cum_s + 1)
        _span._trace_counter = itertools.count(cum_t + 1)

        self.finalize_stats = stats
        return WovenColumns(self, obj_spans, nb, merge_order,
                            tids, psids, net_s0, net_t0)

    def columns(self):
        """Columnar (struct-of-arrays) view of the finished spans.

        Built lazily and cached; feeds :meth:`RunStats.from_columns`, which
        replaces the per-span python reduction loop with numpy passes.  In
        columnar mode the arrays come straight from the emit-time builders
        — no Span round-trip."""
        if self._columns is None:
            if self.columnar:
                self._columns = self.finish_columns().span_columns()
            else:
                from .analysis import SpanColumns
                self._columns = SpanColumns(self.finish())
        return self._columns

    def stats(self) -> Dict[str, Any]:
        """Session-shaped counters (mirrors ``TraceSession.stats``)."""
        span_types: Dict[str, Dict[str, int]] = {}
        pipelines: Dict[str, Dict[str, int]] = {}
        if "net" in self.weavers:
            self._fold_net_counts(self.weavers["net"])
        for st, w in sorted(self.weavers.items()):
            pipelines[st] = {
                "events_in": self.events_in.get(st, 0),
                "events_out": self.events_in.get(st, 0),
                "late_events": w.late_events,
            }
            span_types[st] = dict(w.span_type_counts)
        n_spans = len(self.spans or ())
        if self.spans is None and self._woven is not None:
            n_spans = self._woven.n_spans
        return {
            "state": "done" if self._finished else "running",
            "pipelines": pipelines,
            "context": self.context.stats(),
            "finalize": dict(self.finalize_stats),
            "spans": n_spans,
            "span_types": span_types,
        }

    @property
    def late_events(self) -> int:
        return sum(w.late_events for w in self.weavers.values())

    def _fold_net_counts(self, w: SpanWeaver) -> None:
        """The fused net emit skips the per-span ``span_type_counts`` and
        per-record ``events_in`` bookkeeping; fold the batch tallies in
        (the net weaver emits exactly one span type)."""
        self.events_in["net"] = self._net_count[0]
        n = len(self._net_builder) if self.columnar and self._net_builder else len(w.spans)
        if n:
            w.span_type_counts["LinkTransfer"] = n


def _remap_and_unify(spans: List[Span], span_off: Sequence[int], trace_off: Sequence[int]) -> Dict[int, int]:
    """Renumber tagged ids into the sequential weave's dense blocks AND
    unify trace ids through the parent graph, in one rewrite.

    Equivalent to ``_remap_ids`` followed by
    :func:`~repro.core.weaver.unify_trace_ids`, fused: the parent-chain
    root resolution runs on the *tagged* ids (the tagged -> final map is a
    bijection, so chains resolve identically) and every SpanContext is
    rebuilt exactly once with both the final ids and the unified trace.
    Mirrors unify's edge semantics: a parent whose span was never woven
    keeps its own (remapped) trace id, and chain walks cap at 10k hops.

    Returns the ``tagged span id -> tagged unified trace id`` root map so
    the columnar finish can resolve net-row parents against it without
    re-walking the graph."""
    SC = SpanContext
    BITS = _TAG_BITS
    MASK = _TAG_MASK
    parent_of: Dict[int, int] = {}
    trace_own: Dict[int, int] = {}
    for s in spans:
        ctx = s.context
        sid = ctx.span_id
        p = s.parent
        if p is not None:
            parent_of[sid] = p.span_id
        trace_own[sid] = ctx.trace_id
    root: Dict[int, int] = {}
    root_get = root.get
    pget = parent_of.get
    for s in spans:
        cur = s.context.span_id
        if cur in root:
            continue
        chain = []
        while True:
            r = root_get(cur)
            if r is not None:
                break
            chain.append(cur)
            p = pget(cur)
            if p is None or p not in trace_own or len(chain) > 10000:
                r = trace_own[cur]
                break
            cur = p
        for c in chain:
            root[c] = r
    for s in spans:
        ctx = s.context
        sid = ctx.span_id
        t = root[sid]
        s.context = SC((t & MASK) + trace_off[t >> BITS],
                       (sid & MASK) + span_off[sid >> BITS])
        p = s.parent
        if p is not None:
            psid = p.span_id
            pt = root_get(psid)
            if pt is None:
                pt = p.trace_id   # parent never woven: remap-only, like unify
            s.parent = SC((pt & MASK) + trace_off[pt >> BITS],
                          (psid & MASK) + span_off[psid >> BITS])
        links = s.links
        if links:
            for i, l in enumerate(links):
                t = l.trace_id
                lsid = l.span_id
                links[i] = SC((t & MASK) + trace_off[t >> BITS],
                              (lsid & MASK) + span_off[lsid >> BITS])
    return root


def _sort_spans(spans: List[Span]) -> None:
    """Canonical ``(trace_id, start, span_id)`` order.  The key is a total
    order (span ids are unique), so the columnar argsort and the python
    tuple sort agree exactly; numpy just gets there faster at 1M+ spans."""
    if _np is not None and len(spans) >= 4096:
        n = len(spans)
        tid = _np.empty(n, dtype=_np.int64)
        start = _np.empty(n, dtype=_np.int64)
        sid = _np.empty(n, dtype=_np.int64)
        for i, s in enumerate(spans):
            ctx = s.context
            tid[i] = ctx.trace_id
            start[i] = s.start
            sid[i] = ctx.span_id
        order = _np.lexsort((sid, start, tid))
        spans[:] = [spans[i] for i in order.tolist()]
    else:
        spans.sort(key=lambda s: (s.context.trace_id, s.start, s.context.span_id))


def _coerced_net_attrs(chunk, size, meta, _cv=coerce_value, _NUM=_NUM_LEAD):
    """The object net path's attrs dict ({chunk, size, **coerced meta}),
    built on demand at materialize time instead of per record."""
    attrs = {"chunk": chunk, "size": size}
    for k, v in meta.items():
        t = type(v)
        if t is int or (t is str and (not v or v[0] not in _NUM)):
            attrs[k] = v
        else:
            attrs[k] = _cv(v)
    return attrs


class WovenColumns:
    """A finished columnar weave: sorted object-path spans (host/device —
    the minority) plus the net rows still in column form, joined by one
    merged canonical ``(trace_id, start, span_id)`` order.

    The array-native consumers never leave this representation:
    :meth:`render_jsonl` streams byte-identical SpanJSONL straight from
    the arrays (``core.exporters.render_woven_jsonl``) and
    :meth:`span_columns` builds the analysis :class:`SpanColumns` without
    a Span round-trip.  :meth:`to_spans` materializes the full Span list
    (cached, and published as ``weaver.spans``) for graph-walking
    consumers — diagnose, Chrome export, ad-hoc inspection."""

    __slots__ = ("weaver", "obj_spans", "nb", "order", "net_tids",
                 "net_psids", "net_s0", "net_t0", "n_net", "_spans",
                 "_span_cols")

    def __init__(self, weaver, obj_spans, nb, order, net_tids, net_psids,
                 net_s0, net_t0):
        self.weaver = weaver
        self.obj_spans = obj_spans
        self.nb = nb
        self.order = order
        self.net_tids = net_tids
        self.net_psids = net_psids
        self.net_s0 = net_s0
        self.net_t0 = net_t0
        self.n_net = len(nb)
        self._spans = None
        self._span_cols = None

    @property
    def n_spans(self) -> int:
        return len(self.obj_spans) + self.n_net

    def render_jsonl(self, path_or_stream, flush_every: int = 1024) -> int:
        """Stream the canonical SpanJSONL artifact from the arrays —
        byte-identical to ``SpanJSONLExporter`` over :meth:`to_spans`,
        without materializing the net spans.  Returns spans written."""
        from .exporters import render_woven_jsonl

        return render_woven_jsonl(self, path_or_stream, flush_every=flush_every)

    def span_columns(self):
        """The analysis :class:`SpanColumns`, built array-to-array (net
        durations/codes come straight from the emit-time builders)."""
        if self._span_cols is None:
            from .analysis import SpanColumns

            self._span_cols = SpanColumns.from_woven(self)
        return self._span_cols

    def to_spans(self) -> List[Span]:
        """Materialize the merged Span list (cached).  Bit-for-bit the
        object path's output: same contexts, parents, attr dict order,
        events, and canonical ordering."""
        if self._spans is not None:
            return self._spans
        nb = self.nb
        n = self.n_net
        m = len(self.obj_spans)
        SC = SpanContext
        ev_by_row: Dict[int, list] = {}
        for row, ts, kind, size, meta in nb.events:
            ev_by_row.setdefault(row, []).append((ts, kind, size, meta))
        net_spans: List[Optional[Span]] = [None] * n
        starts = nb.starts
        ends = nb.ends
        chunks = nb.chunks
        sizes = nb.sizes
        metas = nb.metas
        pool = nb.comp_pool
        codes = nb.comp_codes
        tids = self.net_tids
        psids = self.net_psids
        unclosed = nb.unclosed
        s0 = self.net_s0
        for i in range(n):
            chunk = chunks[i]
            attrs = _coerced_net_attrs(chunk, sizes[i], metas[i])
            for ch in nb.xorders[i]:
                if ch == "q":
                    attrs["queue_ps"] = nb.queues[i]
                else:
                    attrs["drops"] = nb.drops[i]
            if i in unclosed:
                attrs["unclosed"] = True
            tid = tids[i]
            psid = psids[i]
            sp = Span(name="LinkTransfer", start=starts[i], end=ends[i],
                      context=SC(tid, s0 + i + 1),
                      parent=SC(tid, psid) if psid >= 0 else None,
                      component=pool[codes[i]], sim_type="net", attrs=attrs)
            evs = ev_by_row.get(i)
            if evs is not None:
                sp.events = [
                    (ts, kind, _coerced_net_attrs(chunk, esize, emeta))
                    for ts, kind, esize, emeta in evs
                ]
            net_spans[i] = sp
        order = self.order
        if not isinstance(order, list):
            order = order.tolist()
        obj = self.obj_spans
        merged = [obj[j] if j < m else net_spans[j - m] for j in order]
        self._spans = merged
        self.weaver.spans = merged
        return merged


class InlineTraceSession:
    """The ``TraceSession``-shaped result of an inline-woven run.

    Scenario code and callers that only read ``spans`` / ``export`` /
    ``stats`` work unchanged whichever weave path produced the run."""

    def __init__(self, weaver: StreamingWeaver) -> None:
        self.weaver = weaver
        self.state = "done"

    @property
    def spans(self) -> List[Span]:
        return self.weaver.spans or []

    def columns(self):
        return self.weaver.columns()

    @property
    def context(self) -> ContextRegistry:
        return self.weaver.context

    @property
    def finalize_stats(self) -> Dict[str, int]:
        return self.weaver.finalize_stats

    @property
    def late_events(self) -> int:
        return self.weaver.late_events

    def export(self, *exporters) -> None:
        from .session import stream_to

        stream_to(self.spans, exporters)

    def stats(self) -> Dict[str, Any]:
        return self.weaver.stats()
