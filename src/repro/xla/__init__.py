from .hlo_stats import (
    COLLECTIVE_KINDS,
    collective_stats,
    cost_summary,
    parse_shape_bytes,
)

__all__ = ["COLLECTIVE_KINDS", "collective_stats", "cost_summary", "parse_shape_bytes"]
