"""Simulator-specific log parsers (Columbo §3.4, 'producers' input side).

Each component simulator writes an *ad-hoc text format* (this is the premise
of the paper: there is no standardization across simulators).  The three
formats below deliberately mimic the flavour of the simulators the paper
used, and the parsers turn each into the standardized type-specific event
stream of core/events.py:

  device sim  — gem5-flavoured:
      ``<tick>: system.pod0.chip03: OpBegin: op=fusion.12 flops=1024 ...``
  host sim    — SimBricks nicbm/i40e-flavoured:
      ``main_time = <tick>: hostsim-host0: ev=data_load_begin step=3 ...``
  net sim     — ns3 ascii-trace-flavoured ('+' enqueue, '-' tx, 'r' rx):
      ``+ 0.001234567890 /IciList/pod0/l3 size=65536 chunk=c42 ...``

A parser is a callable ``line -> Optional[Event]`` plus a ``sim_type``.
Unparseable lines return None (simulators interleave free-form debug text —
also true of gem5/ns3).
"""
from __future__ import annotations

from sys import intern as _intern
from typing import Any, Dict, Optional

from .events import (
    ChunkDrop,
    ChunkEnqueue,
    ChunkRx,
    ChunkTx,
    Event,
    SimType,
    event_types,
)

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


# Leading characters a numeric token can start with (int or float literal,
# including inf/Infinity/nan and whitespace-padded forms).  Anything else
# cannot coerce, so _coerce can skip the exception-based probe entirely —
# raising and catching ValueError on every identifier-shaped token ("host0",
# "ici.pod0.l1", ...) dominated parse/weave profiles at 256 pods.
_NUM_LEAD = frozenset("+-.0123456789iInN \t")


def _coerce(v: str) -> Any:
    """Fast-ish str -> int/float/str coercion."""
    if not v or v[0] not in _NUM_LEAD:
        return v
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def coerce_value(v: Any) -> Any:
    """Normalize one attr value to exactly what the text round-trip yields.

    The text path formats every value with ``f"{v}"`` and re-coerces the
    token with :func:`_coerce`; the structured fast path must agree so the
    two paths weave byte-identical spans.  ``int``/``float`` survive the
    round-trip unchanged (``float(repr(x)) == x`` in Python 3), strings
    re-coerce in place, and anything else (bools, None, ...) normalizes to
    whatever its formatted token coerces to (e.g. ``True`` -> ``"True"``).
    """
    t = type(v)
    if t is int or t is float:
        return v
    if t is str:
        return _coerce(v)
    return _coerce(str(v))


def _parse_kv(parts: list) -> Dict[str, Any]:
    attrs: Dict[str, Any] = {}
    for p in parts:
        eq = p.find("=")
        if eq > 0:
            attrs[p[:eq]] = _coerce(p[eq + 1 :])
    return attrs


class LogParser:
    """Base: callable line parser for one simulator's log format."""

    sim_type: SimType

    def __call__(self, line: str) -> Optional[Event]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# DEVICE: gem5-flavoured
# ---------------------------------------------------------------------------

# CamelCase class-name -> registered snake_case kind.  The device simulator
# logs bare gem5-ish names ("DmaRecv"), so strip our "Device" prefix aliases.
# Public: the structured fast path (sim/clock.py StructuredLogWriter) uses
# the same tables to materialize Events without a text round-trip.
DEVICE_NAME_TO_CLASS: Dict[str, type] = {}
for _kind, _cls in event_types(SimType.DEVICE).items():
    DEVICE_NAME_TO_CLASS[_cls.__name__] = _cls
    if _cls.__name__.startswith("Device"):
        DEVICE_NAME_TO_CLASS[_cls.__name__[6:]] = _cls
_DEVICE_NAME_TO_CLS = DEVICE_NAME_TO_CLASS


class DeviceLogParser(LogParser):
    """``<tick>: system.<pod>.<chip>: <EventClassName>: k=v k=v ...``"""

    sim_type = SimType.DEVICE

    def __call__(self, line: str) -> Optional[Event]:
        # fast path: must start with a digit and contain ": system."
        if not line or not line[0].isdigit():
            return None
        try:
            ts_s, rest = line.split(": ", 1)
            src_s, rest = rest.split(": ", 1)
        except ValueError:
            return None
        if not src_s.startswith("system."):
            return None
        if ": " in rest:
            name, kv = rest.split(": ", 1)
            parts = kv.split()
        else:
            name, parts = rest.strip(), []
        cls = _DEVICE_NAME_TO_CLS.get(name)
        if cls is None:
            return None
        # source: "system.pod0.chip03" -> "pod0.chip03" (interned: a few
        # distinct components repeat across millions of lines)
        return cls(ts=int(ts_s), source=_intern(src_s[7:]), attrs=_parse_kv(parts))


# ---------------------------------------------------------------------------
# HOST: SimBricks nicbm-flavoured
# ---------------------------------------------------------------------------

HOST_KIND_TO_CLASS: Dict[str, type] = event_types(SimType.HOST)
_HOST_KIND_TO_CLS = HOST_KIND_TO_CLASS


class HostLogParser(LogParser):
    """``main_time = <tick>: hostsim-<host>: ev=<kind> k=v ...``"""

    sim_type = SimType.HOST

    def __call__(self, line: str) -> Optional[Event]:
        if not line.startswith("main_time = "):
            return None
        try:
            ts_s, rest = line[12:].split(": ", 1)
            src_s, kv = rest.split(": ", 1)
        except ValueError:
            return None
        if not src_s.startswith("hostsim-"):
            return None
        attrs = _parse_kv(kv.split())
        kind = attrs.pop("ev", None)
        cls = _HOST_KIND_TO_CLS.get(kind)
        if cls is None:
            return None
        return cls(ts=int(ts_s), source=_intern(src_s[8:]), attrs=attrs)


# ---------------------------------------------------------------------------
# NET: ns3 ascii-trace-flavoured
# ---------------------------------------------------------------------------

NET_MARK_TO_CLASS: Dict[str, type] = {
    "+": ChunkEnqueue, "-": ChunkTx, "r": ChunkRx, "d": ChunkDrop,
}
_NET_MARK_TO_CLS = NET_MARK_TO_CLASS


class NetLogParser(LogParser):
    """``<mark> <time_s> <link_path> k=v k=v ...`` with mark in {+,-,r,d}."""

    sim_type = SimType.NET

    def __call__(self, line: str) -> Optional[Event]:
        if not line or line[0] not in "+-rd" or len(line) < 3 or line[1] != " ":
            return None
        parts = line.split()
        if len(parts) < 3:
            return None
        cls = _NET_MARK_TO_CLS[parts[0]]
        try:
            ts = int(round(float(parts[1]) * 1_000_000_000_000))  # s -> ps
        except ValueError:
            return None
        link = parts[2]
        if link.startswith("/"):
            link = link[1:].replace("/", ".")
        return cls(ts=ts, source=_intern(link), attrs=_parse_kv(parts[3:]))


# Retained for backward compatibility; the authoritative binding lives in
# core/registry.py where user code can add simulator types at runtime.
PARSERS = {
    SimType.DEVICE: DeviceLogParser,
    SimType.HOST: HostLogParser,
    SimType.NET: NetLogParser,
}


def parser_for(sim_type) -> LogParser:
    """Instantiate the registered parser for ``sim_type`` (``SimType`` or
    str, including user-registered custom types).  Raises
    :class:`~repro.core.errors.UnknownSimTypeError` for unknown types."""
    from .registry import DEFAULT_REGISTRY  # late import: registry registers us

    return DEFAULT_REGISTRY.make_parser(sim_type)
