"""Quickstart: simulate a 2-pod training run, weave Columbo traces, analyze.

    PYTHONPATH=src python examples/quickstart.py

This is the paper's §3 pipeline end to end: component simulators write
ad-hoc logs -> simulator-specific pipelines parse them into type-specific
event streams -> SpanWeavers assemble spans with implicit cross-simulator
context propagation -> exporters emit Jaeger/Chrome/OTLP traces.
"""
import os
import tempfile

from repro.core import (
    ChromeTraceExporter,
    ConsoleExporter,
    JaegerJSONExporter,
    TraceSession,
    assemble_traces,
    component_breakdown,
    critical_path,
    trace_summary,
)
from repro.sim import run_training_sim, synthetic_program


def main() -> None:
    outdir = os.environ.get("QUICKSTART_OUT", "results/quickstart")
    os.makedirs(outdir, exist_ok=True)

    # 1. a miniature training program: 2 FSDP layers + cross-pod grad sync
    program = synthetic_program(
        n_layers=2, layer_flops=5e11, layer_bytes=2e8, grad_bytes=1e8, cross_pod=True
    )

    # 2. full-system simulation: 2 pods x 4 chips, hosts, ICI/DCN/PCIe
    logdir = os.path.join(outdir, "logs")
    cluster = run_training_sim(program, n_steps=2, n_pods=2, chips_per_pod=4, outdir=logdir)
    print(f"simulated {cluster.sim.events_executed} DES events, "
          f"virtual time {cluster.sim.now / 1e12 * 1e3:.2f} ms")

    # 3. TraceSession: one pipeline per simulator log; the sim type comes
    #    from the registry tag each simulator writes into its log, and the
    #    attached exporters consume spans as they stream out of run()
    session = TraceSession()
    for paths in cluster.log_paths().values():
        for p in paths:
            session.add_log(p)              # sim type auto-detected
    session.attach(
        JaegerJSONExporter(os.path.join(outdir, "trace.jaeger.json")),
        ChromeTraceExporter(os.path.join(outdir, "trace.chrome.json")),
    )
    spans = session.run()
    print("weave:", trace_summary(spans))
    print("context:", session.stats()["context"], "finalize:", session.stats()["finalize"])
    print(f"wrote {outdir}/trace.jaeger.json (Jaeger UI) and trace.chrome.json (Perfetto)")

    # 5. analysis: breakdown + critical path of step 0
    traces = assemble_traces(spans)
    step0 = next(t for t in traces.values() if any(s.name == "HostStep" for s in t.spans))
    print("\nper-component breakdown of step 0 (us):")
    for comp, us in sorted(component_breakdown(step0).items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {comp:28s} {us:10.1f}")
    print("\ncritical path:")
    for s in critical_path(step0):
        print(f"  {s.name:16s} [{s.component}] {s.duration / 1e6:.1f} us")

    print("\nconsole view (truncated):")
    ConsoleExporter(max_spans=25).export(spans)


if __name__ == "__main__":
    main()
