"""Built-in workloads beyond the classic collective-training step.

Importing this package registers the built-ins on the workload registry
(:mod:`repro.sim.workload`), the same pattern ``core.registry`` uses for
simulator types:

* ``rpc``      — :class:`~repro.sim.workloads.rpc.RpcServing`:
  request/response serving with open-loop Poisson or closed-loop arrivals,
  fan-out across pods over the interconnect, and a per-request
  trace-context id that weaves into one end-to-end span tree per request.
* ``storage``  — :class:`~repro.sim.workloads.storage.StorageIO`:
  bulk checkpoint write/read flows contending with training traffic on the
  shared DCN links.
* ``pipeline`` — :class:`~repro.sim.workloads.pipeline.PipelinedTraining`:
  stage-partitioned training with inter-stage activations over the fabric.

The ``rpc`` workload's serving mode selects its frontend load balancer
from a third registry (:mod:`repro.sim.workloads.lb`): ``round_robin``,
``least_loaded``, ``power_of_two_choices``, or any policy registered with
:func:`register_lb_policy`.

``docs/workloads.md`` is the cookbook: each workload's knobs, the span
tree it weaves into, and the "write your own Workload" recipe.
"""
from .lb import (LbPolicy, lb_policy_type, list_lb_policies, make_lb_policy,
                 register_lb_policy)
from .pipeline import PipelinedTraining
from .rpc import RpcServing, rpc_handler_program
from .storage import StorageIO

__all__ = [
    "LbPolicy",
    "PipelinedTraining",
    "RpcServing",
    "StorageIO",
    "lb_policy_type",
    "list_lb_policies",
    "make_lb_policy",
    "register_lb_policy",
    "rpc_handler_program",
]
