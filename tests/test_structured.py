"""Structured zero-parse fast path: byte-identity with the text path,
satellite bugfixes (CRLF stripping, merge tie-breaks), batched weaver
dispatch, buffered JSONL export, and the columnar analysis backend.

The contract under test everywhere: the structured path (simulators hand
``Event`` records straight to the weavers) produces **byte-identical
SpanJSONL** to the text path (format -> parse round-trip) — same goldens,
same sweeps, any seed.
"""
import gc
import gzip
import io
import json
import os

import pytest

from _hypothesis_compat import given, settings, st

from repro.core.analysis import percentile, percentiles
from repro.core.context import ContextRegistry
from repro.core.events import HostStepBegin, OpBegin, OpEnd, ProgramEnd, ProgramStart
from repro.core.exporters import SpanJSONLExporter
from repro.core.parsers import HostLogParser, coerce_value
from repro.core.pipeline import IterableProducer, LogFileProducer, MergedProducer
from repro.core.span import Span, SpanContext
from repro.core.weaver import DeviceSpanWeaver
from repro.sim import EventKernel, StructuredLogWriter, get_scenario, list_scenarios
from repro.sim.sweep import SweepSpec, run_sweep

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")


# ---------------------------------------------------------------------------
# Tentpole: structured path == text path, byte for byte
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name,seed",
    [("healthy_baseline", 0), ("degraded_ici_link", 3)],
)
def test_structured_span_jsonl_matches_golden(name, seed):
    """The fast path must reproduce the *pre-refactor* golden bytes — the
    same files the text path is held to in tests/test_sweep.py."""
    path = os.path.join(GOLDEN_DIR, f"scenario.{name}.seed{seed}.spans.jsonl.gz")
    with gzip.open(path, "rb") as f:
        golden = f.read().decode()
    run = get_scenario(name).run(seed=seed, structured=True)
    assert run.span_jsonl == golden, (
        f"{name} seed={seed}: structured SpanJSONL diverged from the golden "
        f"({len(run.span_jsonl)} vs {len(golden)} bytes)"
    )


@pytest.mark.parametrize("name", list_scenarios())
def test_structured_equals_text_all_scenarios(name):
    """Every curated scenario weaves identically on both paths (fixed
    seed; the hypothesis property below widens this to arbitrary seeds)."""
    spec = get_scenario(name)
    text = spec.run(seed=11)
    fast = spec.run(seed=11, structured=True)
    assert fast.span_jsonl == text.span_jsonl
    assert fast.detected == text.detected
    assert fast.ok == text.ok


@given(
    seed=st.integers(min_value=0, max_value=2**32 - 1),
    name=st.sampled_from(list_scenarios()),
)
@settings(max_examples=10, deadline=None)
def test_structured_equals_text_any_seed(seed, name):
    """Property: for any scenario and any seed, structured == text bytes."""
    spec = get_scenario(name)
    assert (
        spec.run(seed=seed, structured=True).span_jsonl
        == spec.run(seed=seed).span_jsonl
    )


def test_structured_sweep_shards_byte_identical(tmp_path):
    """--jobs N structured sweeps write the same shard bytes as the serial
    text sweep: the fast path composes with parallel execution."""
    spec = SweepSpec(scenarios=("healthy_baseline", "gc_pause_host0"), seeds=(0, 3))
    text = run_sweep(spec, str(tmp_path / "text"), jobs=1)
    fast = run_sweep(spec, str(tmp_path / "fast"), jobs=2, structured=True)
    assert [(c.scenario, c.workload, c.mitigation, c.magnitude, c.rate, c.seed)
            for c in fast.cells] == spec.cells()
    for ct, cf in zip(text.cells, fast.cells):
        with open(os.path.join(text.outdir, ct.shard), "rb") as f:
            b_text = f.read()
        with open(os.path.join(fast.outdir, cf.shard), "rb") as f:
            b_fast = f.read()
        assert b_text == b_fast, (
            f"cell ({ct.scenario}, {ct.seed}): structured --jobs 2 shard "
            f"differs from the text --jobs 1 shard"
        )
        assert ct.stats.detected == cf.stats.detected
    with open(os.path.join(fast.outdir, "sweep.json")) as f:
        assert json.load(f)["structured"] is True


def test_structured_writer_renders_the_text_log(tmp_path):
    """render_lines() reproduces the ad-hoc text log byte for byte — the
    format stage is a pure function of the captured records."""
    from repro.sim.cluster import ClusterOrchestrator, drive_training_hosts
    from repro.sim.topology import scale
    from repro.sim.workload import synthetic_program

    def simulate(structured, outdir=None):
        program = synthetic_program(
            n_layers=1, layer_flops=1e11, layer_bytes=1e8, grad_bytes=1e7
        )
        cluster = ClusterOrchestrator(
            scale(pods=2, chips_per_pod=2), outdir=outdir, structured=structured
        )
        drive_training_hosts(cluster, program, 1)
        cluster.run()
        return cluster

    text = simulate(False, outdir=str(tmp_path))
    fast = simulate(True)
    assert len(fast._logs) == len(text._logs)
    # writers are created in the same order as the text logs
    for lw_fast, lw_text in zip(fast._logs, text._logs):
        with open(lw_text.path, newline="") as f:
            disk = f.read().splitlines()
        assert lw_fast.render_lines() == disk


def test_events_does_not_corrupt_the_capture():
    """Materializing events must not rewrite the captured records: a
    string attr whose coerced form formats differently (\"1_000\" is a
    valid int literal) still renders as originally emitted afterwards."""
    lw = StructuredLogWriter("host")
    lw.emit_host((5, "host0", "step_begin", {"step": 0, "tag": "1_000"}))
    evs = list(lw.events())
    assert evs[0].attrs["tag"] == 1000          # event side: coerced
    assert lw.render_lines() == [
        "main_time = 5: hostsim-host0: ev=step_begin step=0 tag=1_000"
    ]                                           # replay side: pristine


def test_structured_writer_unknown_sim_type_raises():
    lw = StructuredLogWriter("storage")
    lw.emit_host((0, "h", "step_begin", {}))
    with pytest.raises(ValueError, match="storage"):
        list(lw.events())


def test_coerce_value_matches_text_round_trip():
    """Structured attr normalization == format-with-f-string + re-coerce."""
    from repro.core.parsers import _coerce

    for v in (7, -3, 0, 2.5, 1e-9, "chip00", "42", "4.5", "ar1.s0", True, None):
        assert coerce_value(v) == _coerce(f"{v}")


# ---------------------------------------------------------------------------
# Satellite: CRLF logs parse cleanly (LogFileProducer stripped only "\n")
# ---------------------------------------------------------------------------


def test_log_file_producer_strips_crlf(tmp_path):
    """A CRLF-terminated log must not leak '\\r' into the last k=v token."""
    path = tmp_path / "host.crlf.log"
    lines = [
        "main_time = 100: hostsim-host0: ev=step_begin step=3",
        "main_time = 200: hostsim-host0: ev=data_load_begin step=3",
    ]
    # newline="" writes the CRLF endings verbatim (no translation)
    with open(path, "w", newline="") as f:
        for line in lines:
            f.write(line + "\r\n")
    evs = list(LogFileProducer(path, HostLogParser()).events())
    assert [e.kind for e in evs] == ["step_begin", "data_load_begin"]
    for e in evs:
        # pre-fix, the trailing token parsed as "3\r" (a corrupt string
        # attr) instead of the integer 3
        assert e.attrs["step"] == 3


# ---------------------------------------------------------------------------
# Satellite: MergedProducer tie-break on interleaved shards
# ---------------------------------------------------------------------------


def _op(ts, chip, i):
    return OpBegin(ts=ts, source=chip, attrs={"op": f"op{i}"})


def test_merged_producer_interleaved_shards_tie_break():
    """Interleaved timestamps merge into global time order; *equal*
    timestamps break toward the earlier-listed shard (heapq.merge
    semantics the structured shard merge also relies on)."""
    shard_a = [_op(10, "a", 0), _op(30, "a", 1), _op(30, "a", 2), _op(50, "a", 3)]
    shard_b = [_op(20, "b", 0), _op(30, "b", 1), _op(40, "b", 2)]
    merged = list(
        MergedProducer([IterableProducer(shard_a), IterableProducer(shard_b)]).events()
    )
    assert [e.ts for e in merged] == [10, 20, 30, 30, 30, 40, 50]
    # at ts=30: both of shard A's events precede shard B's
    assert [(e.ts, e.source) for e in merged][2:5] == [(30, "a"), (30, "a"), (30, "b")]
    # swapping the shard list flips the tie-break deterministically
    flipped = list(
        MergedProducer([IterableProducer(shard_b), IterableProducer(shard_a)]).events()
    )
    assert [(e.ts, e.source) for e in flipped][2:5] == [(30, "b"), (30, "a"), (30, "a")]


# ---------------------------------------------------------------------------
# Batched weaver dispatch + buffered JSONL export
# ---------------------------------------------------------------------------


def _device_events():
    evs = [ProgramStart(ts=0, source="pod0.chip00", attrs={"program": "p", "step": 0})]
    for i in range(50):
        t = 100 + i * 100
        evs.append(OpBegin(ts=t, source="pod0.chip00", attrs={"op": f"op{i}", "step": 0}))
        evs.append(OpEnd(ts=t + 60, source="pod0.chip00", attrs={"op": f"op{i}", "step": 0}))
    evs.append(ProgramEnd(ts=10_000, source="pod0.chip00", attrs={"program": "p", "step": 0}))
    return evs


def test_consume_many_equals_per_event_consume():
    def weave(batched):
        w = DeviceSpanWeaver(ContextRegistry())
        evs = _device_events()
        # a host-only kind the device weaver has no handler for exercises
        # the unhandled counter on both paths
        evs.insert(3, HostStepBegin(ts=150, source="pod0.chip00", attrs={"step": 0}))
        if batched:
            assert w.consume_many(iter(evs)) == len(evs)
        else:
            for ev in evs:
                w.consume(ev)
        w.on_finish()
        return w

    a, b = weave(False), weave(True)
    assert a.unhandled_events == b.unhandled_events == 1
    assert [(s.name, s.start, s.end) for s in a.spans] == [
        (s.name, s.start, s.end) for s in b.spans
    ]


def test_span_jsonl_exporter_buffering_matches_unbuffered(tmp_path):
    spans = [
        Span(
            name=f"S{i}", start=i * 10, end=i * 10 + 5,
            context=SpanContext(trace_id=1, span_id=i + 1),
            component="c0", sim_type="device", attrs={"i": i},
        )
        for i in range(10)
    ]
    buf_small, buf_big = io.StringIO(), io.StringIO()
    e1 = SpanJSONLExporter(buf_small, flush_every=2)   # forces mid-stream flushes
    e1.export(spans)
    e2 = SpanJSONLExporter(buf_big)                    # everything flushed at finish
    e2.export(spans)
    assert buf_small.getvalue() == buf_big.getvalue()
    assert e1.spans_written == e2.spans_written == 10
    path = tmp_path / "spans.jsonl"
    e3 = SpanJSONLExporter(str(path), flush_every=3)
    e3.export(spans)
    assert path.read_text() == buf_small.getvalue()


# ---------------------------------------------------------------------------
# Columnar analysis backend: numpy and pure python agree bit for bit
# ---------------------------------------------------------------------------


def test_percentiles_columnar_matches_pure_python():
    numpy = pytest.importorskip("numpy")
    rng = numpy.random.default_rng(7)
    samples = [float(x) for x in rng.gamma(2.0, 50.0, size=5000)]
    got = percentiles(samples, (50, 90, 99, 100))
    s = sorted(samples)
    n = len(s)
    for q, v in zip((50, 90, 99, 100), got):
        pos = (n - 1) * q / 100.0
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        assert v == s[lo] + (s[hi] - s[lo]) * (pos - lo)   # exact, not approx
    assert percentile(samples, 99) == got[2]
    assert percentiles([], (50, 99)) == [0.0, 0.0]


def test_median_columnar_matches_statistics():
    numpy = pytest.importorskip("numpy")
    import statistics

    from repro.core.analysis import _median

    rng = numpy.random.default_rng(3)
    for n in (64, 65, 1001):
        vals = [float(x) for x in rng.normal(100.0, 15.0, size=n)]
        assert _median(vals) == statistics.median(vals)


# ---------------------------------------------------------------------------
# Kernel: call_at ordering + the GC pause around run()
# ---------------------------------------------------------------------------


def test_call_at_interleaves_with_at_in_seq_order():
    k = EventKernel()
    fired = []
    k.at(10, lambda: fired.append("a"))
    k.call_at(10, lambda: fired.append("b"))
    k.at(10, lambda: fired.append("c"))
    k.call_at(5, lambda: fired.append("first"))
    k.run()
    assert fired == ["first", "a", "b", "c"]


def test_run_restores_gc_even_on_callback_error():
    assert gc.isenabled()
    k = EventKernel()

    def boom():
        assert not gc.isenabled()       # paused inside the drain
        raise RuntimeError("boom")

    k.call_at(1, boom)
    with pytest.raises(RuntimeError):
        k.run()
    assert gc.isenabled()
